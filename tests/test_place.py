"""Placement tests: traffic graph, annealer determinism, route rewrite,
and the core invariant — placement can only change *performance*, never
*results*: any valid core permutation keeps every circuit bit-exact
against the netlist oracle."""
import random

import pytest

from repro.circuits import CIRCUITS, FINISH, build
from repro.core.compile import compile_circuit
from repro.core.interpreter import NetlistSim
from repro.core.isa import HardwareConfig
from repro.core.isasim import IsaSim
from repro.core.lower import lower
from repro.core.opt import optimize_lowered
from repro.core.partition import partition
from repro.core.place import (hop_cost, place, traffic_graph,
                              weighted_cost)

NAMES = sorted(CIRCUITS)
HW = HardwareConfig(grid_width=5, grid_height=5)


def _middle_end(name: str, scale: str = "small"):
    b = build(name, scale)
    low = lower(b.circuit)
    low, _ = optimize_lowered(low)
    part = partition(low, HW.num_cores, "balanced")
    return b, low, part


# ---------------------------------------------------------------------
# traffic graph
# ---------------------------------------------------------------------

def test_traffic_graph_edges_and_weights():
    _, low, part = _middle_end("noc")
    g = traffic_graph(low, part, HW)
    pairs = {(e.src_proc, e.dst_proc) for e in part.sends}
    assert set(g) == pairs
    # each SendEdge contributes 1 + crit with crit in [0, 1]
    n_sends = len(part.sends)
    assert n_sends <= sum(g.values()) <= 2 * n_sends
    counts = {}
    for e in part.sends:
        k = (e.src_proc, e.dst_proc)
        counts[k] = counts.get(k, 0) + 1
    for k, w in g.items():
        assert counts[k] <= w <= 2 * counts[k], (k, w, counts[k])


def test_cost_helpers_identity_vs_shuffle():
    _, low, part = _middle_end("noc")
    g = traffic_graph(low, part, HW)
    n = part.num_procs
    ident = list(range(n))
    assert weighted_cost(ident, g, HW) >= hop_cost(ident, part.sends, HW)
    # hop_cost is a sum of nonneg torus distances, zero only with no sends
    assert hop_cost(ident, part.sends, HW) > 0


# ---------------------------------------------------------------------
# annealer
# ---------------------------------------------------------------------

def test_place_deterministic_under_fixed_seed():
    _, low, part = _middle_end("noc")
    a = place(low, part, HW, strategy="anneal", seed=0)
    b = place(low, part, HW, strategy="anneal", seed=0)
    assert a.core_of_proc == b.core_of_proc
    assert a.stats["total_hops"] == b.stats["total_hops"]
    assert a.stats["weighted_hops"] == b.stats["weighted_hops"]


def test_place_never_worse_than_identity_in_objective():
    for nm in ("noc", "mc", "bc"):
        _, low, part = _middle_end(nm)
        g = traffic_graph(low, part, HW)
        pl = place(low, part, HW, strategy="anneal")
        n = part.num_procs
        assert sorted(pl.core_of_proc) == sorted(set(pl.core_of_proc))
        assert len(pl.core_of_proc) == n
        w_pl = weighted_cost(pl.core_of_proc, g, HW)
        w_id = weighted_cost(list(range(n)), g, HW)
        assert w_pl <= w_id


def test_place_identity_strategy_is_identity():
    _, low, part = _middle_end("mc")
    pl = place(low, part, HW, strategy="identity")
    assert pl.core_of_proc == list(range(part.num_procs))


def test_place_rejects_unknown_strategy():
    _, low, part = _middle_end("blur")
    with pytest.raises(ValueError):
        place(low, part, HW, strategy="magic")


# ---------------------------------------------------------------------
# route rewrite through compile_circuit
# ---------------------------------------------------------------------

def test_explicit_placement_rewrites_routes():
    b = build("noc", "small")
    p0 = compile_circuit(b.circuit, HW, placement="identity")
    n = p0.stats["procs"]
    rnd = random.Random(7)
    cop = rnd.sample(range(HW.num_cores), n)
    p1 = compile_circuit(b.circuit, HW, placement=cop)
    assert p1.stats["placement"] == "explicit"
    assert p1.used_cores == max(cop) + 1
    # every exchange entry routes between *placed* cores
    placed = set(cop)
    for s, d in zip(p1.xchg_src_core, p1.xchg_dst_core):
        assert int(s) in placed and int(d) in placed


def test_explicit_placement_validation():
    b = build("blur", "small")
    p0 = compile_circuit(b.circuit, HW, placement="identity")
    n = p0.stats["procs"]
    with pytest.raises(ValueError):
        compile_circuit(b.circuit, HW, placement=[0] * n)   # not distinct
    with pytest.raises(ValueError):
        compile_circuit(b.circuit, HW,
                        placement=list(range(1, n + 1)) + [0])  # wrong len


def test_compile_rejects_unknown_placement():
    b = build("blur", "small")
    with pytest.raises(ValueError):
        compile_circuit(b.circuit, HW, placement="magic")


def test_anneal_never_loses_to_identity():
    """The scheduler-level best-of-two: anneal ships identity's schedule
    whenever the annealed geometry doesn't beat it."""
    for nm in NAMES:
        b = build(nm, "small")
        pa = compile_circuit(b.circuit, HW, placement="anneal")
        pi = compile_circuit(b.circuit, HW, placement="identity")
        assert pa.vcpl <= pi.vcpl, nm
        assert pa.stats["place_pick"] in ("anneal", "identity")
        for k in ("total_hops", "weighted_hops", "place_seconds",
                  "place_moves"):
            assert k in pa.stats, k


# ---------------------------------------------------------------------
# the invariant: placement never changes results
# ---------------------------------------------------------------------

def _assert_bit_exact(b, prog):
    oracle = NetlistSim(b.circuit)
    oracle.run(b.n_cycles + 10)
    sim = IsaSim(prog)
    assert sim.run(b.n_cycles + 10) == b.n_cycles
    assert set(sim.exceptions().values()) == {FINISH}
    for name in prog.state_regs:
        assert sim.read_reg(name) == oracle.reg_value(name), name


@pytest.mark.parametrize("name", NAMES)
def test_random_permutation_bit_exact(name):
    """A seeded random core permutation keeps each of the nine circuits
    bit-exact against the netlist oracle."""
    b = build(name, "small")
    p0 = compile_circuit(b.circuit, HW, placement="identity")
    n = p0.stats["procs"]
    rnd = random.Random(hash(name) & 0xffff)
    cop = rnd.sample(range(HW.num_cores), n)
    prog = compile_circuit(b.circuit, HW, placement=cop)
    _assert_bit_exact(b, prog)


@pytest.mark.parametrize("strategy", ["anneal", "identity"])
@pytest.mark.parametrize("name", NAMES)
def test_placement_strategies_bit_exact(name, strategy):
    b = build(name, "small")
    prog = compile_circuit(b.circuit, HW, placement=strategy, check=True)
    _assert_bit_exact(b, prog)


# ---------------------------------------------------------------------
# hypothesis: arbitrary valid permutations (skipped where unavailable)
# ---------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import HealthCheck, given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data(), name=st.sampled_from(["noc", "bc", "vta"]))
    def test_any_permutation_bit_exact(data, name):
        b = build(name, "small")
        p0 = compile_circuit(b.circuit, HW, placement="identity")
        n = p0.stats["procs"]
        cop = data.draw(st.permutations(range(HW.num_cores)))[:n]
        prog = compile_circuit(b.circuit, HW, placement=list(cop))
        _assert_bit_exact(b, prog)
