"""The optimizing middle-end (PR 3): pass-level unit tests, the IR
invariant checker, the batched-stimulus liveness contract, and the
end-to-end payoff — fewer instructions and lower VCPL at equal semantics.
"""
import pytest

from repro.circuits import CIRCUITS, FINISH, build
from repro.circuits.common import Planes, make_counter
from repro.core.bsp import Machine
from repro.core.compile import compile_circuit
from repro.core.isa import HardwareConfig, Op
from repro.core.isasim import IsaSim
from repro.core.lower import lower
from repro.core.netlist import Circuit
from repro.core.opt import (const_fold, copy_prop, cse, dce, eval_op,
                            maybe_mask, optimize_lowered, strength_reduce)

HW = HardwareConfig(grid_width=5, grid_height=5)


def _ops(low):
    return [i.op for i in low.instrs]


# ----------------------------------------------------------------------
# individual passes
# ----------------------------------------------------------------------

def test_const_fold_collapses_constant_expressions():
    c = Circuit("t")
    r = c.reg(16, init=0, name="r")
    k = (c.const(3, 16) + c.const(4, 16)) * c.const(5, 16)   # == 35
    c.set_next(r, r + k)
    low = lower(c)
    low, _ = optimize_lowered(low)
    # the constant expression folded into a single leaf: the next value is
    # one ADD of the register and a constant
    arith = [i for i in low.instrs if i.op in (Op.ADD, Op.ADDC, Op.MUL)]
    assert len(arith) == 1
    assert 35 in low.const_vregs.values()


def test_strength_reduction_mul_pow2_becomes_shift():
    c = Circuit("t")
    r = c.reg(16, init=3, name="r")
    c.set_next(r, r * 8)
    low = lower(c)
    low, _ = optimize_lowered(low)
    ops = _ops(low)
    assert Op.MUL not in ops and Op.MULH not in ops
    assert Op.SLL in ops
    sll = next(i for i in low.instrs if i.op == Op.SLL)
    assert sll.imm == 3


def test_known_bits_erase_redundant_masking():
    c = Circuit("t")
    r = c.reg(8, init=7, name="r")
    c.set_next(r, (r & 0xFF) ^ 1)   # the AND is a no-op on an 8-bit value
    low = lower(c)
    low, _ = optimize_lowered(low)
    assert Op.AND not in _ops(low)
    assert Op.XOR in _ops(low)


def test_cse_dedups_identical_expressions():
    c = Circuit("t")
    a = c.reg(16, init=1, name="a")
    b = c.reg(16, init=2, name="b")
    c.set_next(a, a + b)
    c.set_next(b, a + b)   # same value: second def must become a copy
    low = lower(c)
    low, _ = optimize_lowered(low)
    adds = [i for i in low.instrs if i.op in (Op.ADD, Op.ADDC)]
    assert len(adds) == 1
    movs = [i for i in low.instrs if i.op == Op.MOV]
    assert len(movs) == 1 and movs[0].srcs == (adds[0].dst,)
    # both registers keep *distinct* next-value definitions (commit sinks)
    nxts = {w for r in low.regs for w in r.nxt}
    assert len(nxts) == 2


def test_dce_removes_unreachable_cones():
    c = Circuit("t")
    r = c.reg(16, init=0, name="r")
    _dead = (r ^ 12345) * 7   # drives nothing
    c.set_next(r, r + 1)
    low = lower(c)
    n_pre = len(low.instrs)
    low, _ = optimize_lowered(low)
    assert len(low.instrs) < n_pre
    assert Op.MUL not in _ops(low) and Op.MULH not in _ops(low)
    assert Op.XOR not in _ops(low)


def test_individual_passes_run_standalone():
    """Each pass is independently callable on a checked IR."""
    b = build("mc", "small")
    low = lower(b.circuit)
    low.check()
    for fn in (const_fold, copy_prop, strength_reduce, cse, dce):
        fn(low)
        low.check()


def test_eval_op_matches_isasim_semantics():
    assert eval_op(Op.SRA, [0x8000], 3) == 0xF000
    assert eval_op(Op.SLICE, [0xABCD], 4 * 32 + 8) == 0xBC
    assert eval_op(Op.BORROW, [0, 1, 0], 0) == 1
    assert eval_op(Op.CARRY, [0xFFFF, 1, 0], 0) == 1
    assert eval_op(Op.MULH, [0x8000, 4], 0) == 2
    assert maybe_mask(Op.CARRY, [0x7FFF, 0x7FFF, 1], 0) == 0
    assert maybe_mask(Op.ADD, [0x0F, 0x0F], 0) == 0x1F


# ----------------------------------------------------------------------
# invariant checker
# ----------------------------------------------------------------------

def test_checker_rejects_constant_marked_state():
    b = build("vta", "small")
    low = lower(b.circuit)
    cur = next(iter(low.state_vregs()))
    low.const_vregs[cur] = 0
    with pytest.raises(AssertionError):
        low.check()


def test_checker_rejects_lost_next_register_def():
    b = build("vta", "small")
    low = lower(b.circuit)
    nxt = low.regs[0].nxt[0]
    low.replace_instrs([i for i in low.instrs if i.writes() != nxt])
    with pytest.raises(AssertionError):
        low.check()


def test_checker_rejects_use_before_def():
    b = build("vta", "small")
    low = lower(b.circuit)
    low.replace_instrs(list(reversed(low.instrs)))
    with pytest.raises(AssertionError):
        low.check()


# ----------------------------------------------------------------------
# batched-stimulus liveness contract
# ----------------------------------------------------------------------

def test_init_plane_carriers_survive_optimization():
    """A ``Planes.hold`` golden value is read by nothing but its own
    self-hold — the passes must still keep it (it carries per-stimulus
    init), and per-seed images must still land correctly."""
    c = Circuit("t")
    planes = Planes(c, 2, live=True)
    ctr = make_counter(c, 16)
    gold = planes.hold([111, 222], 16, "gold")
    acc = planes.reg(16, [5, 9], "acc")
    c.set_next(acc, acc + gold)
    c.finish_when(ctr.eq(6), FINISH)
    prog = compile_circuit(c, HW)
    assert "gold" in prog.state_regs and "acc" in prog.state_regs
    images = [prog.init_images(r, m)
              for r, m in zip(planes.regs, planes.mems)]
    m = Machine(prog)
    for i, (g0, a0) in enumerate([(111, 5), (222, 9)]):
        st = m.run(m.init_state(images=images[i]), 3)
        assert m.read_reg(st, "gold") == g0
        assert m.read_reg(st, "acc") == (a0 + 3 * g0) & 0xFFFF


def test_batched_state_regs_identical_opt_on_off():
    b = build("mc", "small", seeds=[3, 11])
    po = compile_circuit(b.circuit, HW, optimize=True)
    pf = compile_circuit(b.circuit, HW, optimize=False)
    assert set(po.state_regs) == set(pf.state_regs)
    # every plane register is patchable on the optimized program
    for rp, mp in zip(b.reg_planes, b.mem_planes):
        po.init_images(rp, mp)


# ----------------------------------------------------------------------
# end-to-end payoff
# ----------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(CIRCUITS))
def test_optimized_bit_exact_and_smaller(name):
    b = build(name, "small")
    po = compile_circuit(b.circuit, HW, optimize=True)
    pf = compile_circuit(b.circuit, HW, optimize=False)
    so, sf = IsaSim(po), IsaSim(pf)
    assert so.run(b.n_cycles + 10) == sf.run(b.n_cycles + 10) == b.n_cycles
    assert set(so.exceptions().values()) == {FINISH}
    for reg in po.state_regs:
        if reg in pf.state_regs:
            assert so.read_reg(reg) == sf.read_reg(reg), reg
    assert po.stats["instrs_opt"] < po.stats["instrs_lowered"]
    assert po.stats["opt_passes"], "per-pass stats must be recorded"
    assert not pf.stats["optimize"] and not pf.stats["opt_passes"]


@pytest.mark.parametrize("name", ["bc", "jpeg", "mm", "vta", "rv32r"])
def test_vcpl_improves_or_schedule_already_minimal(name):
    """Fewer/simpler instructions must shorten the virtual critical path —
    unless the schedule already sits on its dependence/load lower bound."""
    b = build(name, "small")
    po = compile_circuit(b.circuit, HW, optimize=True)
    pf = compile_circuit(b.circuit, HW, optimize=False)
    assert po.vcpl < pf.vcpl or po.stats["sched_minimal"], \
        (po.vcpl, pf.vcpl, po.stats["crit_path_lb"])


def test_full_scale_instruction_reduction_floor():
    """Acceptance: >= 15% post-lower instruction reduction on at least 5 of
    the 9 full-scale circuits (in practice: all 9)."""
    hits = 0
    for nm in sorted(CIRCUITS):
        b = build(nm, "full")
        p = compile_circuit(b.circuit, HW)
        if p.stats["instrs_opt"] <= 0.85 * p.stats["instrs_lowered"]:
            hits += 1
    assert hits >= 5, hits
